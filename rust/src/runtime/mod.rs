//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them.
//!
//! This is the only place the `xla` crate is touched. The interchange format
//! is **HLO text** (never serialized `HloModuleProto`): jax ≥ 0.5 emits
//! protos with 64-bit instruction ids that xla_extension 0.5.1 rejects,
//! while the text parser reassigns ids and round-trips cleanly (see
//! `/opt/xla-example/README.md` and `python/compile/aot.py`).
//!
//! A [`Runtime`] owns one PJRT client plus the compiled executables of an
//! artifact directory, described by `manifest.json` (written by `aot.py`).
//! PJRT objects are not `Send`; each compnode thread owns its own `Runtime`.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::tensor::Tensor;
use crate::util::json;
use crate::util::Rng;

/// How a parameter tensor is initialized (carried in the manifest so rust
/// can materialize the same init the L2 model expects).
#[derive(Debug, Clone, PartialEq)]
pub enum InitKind {
    Zeros,
    Ones,
    Normal { std: f64 },
}

/// One parameter's spec.
#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub init: InitKind,
}

impl ParamSpec {
    /// Materialize an initial value.
    pub fn materialize(&self, rng: &mut Rng) -> Tensor {
        match self.init {
            InitKind::Zeros => Tensor::zeros(&self.shape),
            InitKind::Ones => {
                Tensor::from_vec(&self.shape, vec![1.0; self.shape.iter().product()])
            }
            InitKind::Normal { std } => Tensor::randn(&self.shape, std as f32, rng),
        }
    }
}

/// One artifact (an AOT-lowered jax function).
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    /// Number of outputs in the result tuple.
    pub n_outputs: usize,
}

/// The manifest of an artifact directory.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub preset: String,
    /// Model config key/values (vocab, seq, batch, layers, dim, …).
    pub config: HashMap<String, f64>,
    pub artifacts: Vec<ArtifactSpec>,
    /// Stage name → ordered parameter specs.
    pub stage_params: HashMap<String, Vec<ParamSpec>>,
    /// Ordered stage names (embed, block0…blockN, head).
    pub stages: Vec<String>,
}

impl Manifest {
    /// Parse `manifest.json`.
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let root = json::parse(&text).map_err(|e| anyhow!("manifest parse: {e}"))?;
        let preset = root
            .get("preset")
            .and_then(|j| j.as_str())
            .ok_or_else(|| anyhow!("manifest missing 'preset'"))?
            .to_string();
        let mut config = HashMap::new();
        if let Some(obj) = root.get("config").and_then(|j| j.as_obj()) {
            for (k, v) in obj {
                if let Some(n) = v.as_f64() {
                    config.insert(k.clone(), n);
                }
            }
        }
        let mut artifacts = Vec::new();
        if let Some(obj) = root.get("artifacts").and_then(|j| j.as_obj()) {
            for (name, spec) in obj {
                artifacts.push(ArtifactSpec {
                    name: name.clone(),
                    file: spec
                        .get("file")
                        .and_then(|j| j.as_str())
                        .ok_or_else(|| anyhow!("artifact {name} missing file"))?
                        .to_string(),
                    n_outputs: spec.get("n_outputs").and_then(|j| j.as_usize()).unwrap_or(1),
                });
            }
        }
        let mut stage_params = HashMap::new();
        if let Some(obj) = root.get("stage_params").and_then(|j| j.as_obj()) {
            for (stage, arr) in obj {
                let mut specs = Vec::new();
                for p in arr.as_arr().unwrap_or(&[]) {
                    let shape: Vec<usize> = p
                        .get("shape")
                        .and_then(|j| j.as_arr())
                        .map(|a| a.iter().filter_map(|d| d.as_usize()).collect())
                        .unwrap_or_default();
                    let init = match p.get("init").and_then(|j| j.as_str()) {
                        Some("zeros") | None => InitKind::Zeros,
                        Some("ones") => InitKind::Ones,
                        Some("normal") => InitKind::Normal {
                            std: p.get("std").and_then(|j| j.as_f64()).unwrap_or(0.02),
                        },
                        Some(other) => bail!("unknown init kind '{other}'"),
                    };
                    specs.push(ParamSpec {
                        name: p
                            .get("name")
                            .and_then(|j| j.as_str())
                            .unwrap_or("param")
                            .to_string(),
                        shape,
                        init,
                    });
                }
                stage_params.insert(stage.clone(), specs);
            }
        }
        let stages: Vec<String> = root
            .get("stages")
            .and_then(|j| j.as_arr())
            .map(|a| a.iter().filter_map(|s| s.as_str().map(str::to_string)).collect())
            .unwrap_or_default();
        Ok(Manifest { preset, config, artifacts, stage_params, stages })
    }

    pub fn config_usize(&self, key: &str) -> Option<usize> {
        self.config.get(key).map(|&v| v as usize)
    }
}

/// PJRT client + compiled executables.
pub struct Runtime {
    client: xla::PjRtClient,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Artifact name → declared `n_outputs` (from the manifest); execute
    /// validates the result tuple against it when present.
    expected_outputs: HashMap<String, usize>,
    dir: PathBuf,
}

impl Runtime {
    /// Create a CPU-backed runtime.
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime {
            client,
            executables: HashMap::new(),
            expected_outputs: HashMap::new(),
            dir: PathBuf::new(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile one HLO-text file under `name`.
    pub fn load_hlo_text(&mut self, name: &str, path: &Path) -> Result<()> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        self.executables.insert(name.to_string(), exe);
        Ok(())
    }

    /// Load every artifact listed in a directory's manifest. Returns the
    /// parsed manifest.
    pub fn load_dir(&mut self, dir: &Path) -> Result<Manifest> {
        let manifest = Manifest::load(&dir.join("manifest.json"))?;
        for a in &manifest.artifacts {
            self.load_hlo_text(&a.name, &dir.join(&a.file))?;
            self.expected_outputs.insert(a.name.clone(), a.n_outputs);
        }
        self.dir = dir.to_path_buf();
        Ok(manifest)
    }

    /// Load only the artifacts whose names pass `filter` (compnodes load
    /// just their own stage's functions).
    pub fn load_dir_filtered(
        &mut self,
        dir: &Path,
        filter: impl Fn(&str) -> bool,
    ) -> Result<Manifest> {
        let manifest = Manifest::load(&dir.join("manifest.json"))?;
        for a in &manifest.artifacts {
            if filter(&a.name) {
                self.load_hlo_text(&a.name, &dir.join(&a.file))?;
                self.expected_outputs.insert(a.name.clone(), a.n_outputs);
            }
        }
        self.dir = dir.to_path_buf();
        Ok(manifest)
    }

    pub fn has(&self, name: &str) -> bool {
        self.executables.contains_key(name)
    }

    pub fn loaded(&self) -> Vec<&str> {
        self.executables.keys().map(String::as_str).collect()
    }

    /// Execute an artifact on literals; the (tuple) result is decomposed
    /// into its elements.
    pub fn execute(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self
            .executables
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not loaded"))?;
        let out = exe.execute::<xla::Literal>(inputs)?;
        let lit = first_result(name, &out)?.to_literal_sync()?;
        let elems = lit.to_tuple()?;
        self.check_arity(name, elems.len())?;
        Ok(elems)
    }

    /// Validate a result tuple against the manifest's declared `n_outputs`
    /// (artifacts loaded directly via [`load_hlo_text`](Self::load_hlo_text)
    /// declare nothing and are exempt).
    fn check_arity(&self, name: &str, got: usize) -> Result<()> {
        if let Some(&want) = self.expected_outputs.get(name) {
            if got != want {
                bail!("artifact '{name}' returned {got} outputs, manifest declares {want}");
            }
        }
        Ok(())
    }

    /// Execute with tensors in / tensors out (the coordinator-facing API).
    pub fn run(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let lits: Vec<xla::Literal> = inputs.iter().map(to_literal).collect::<Result<_>>()?;
        let outs = self.execute(name, &lits)?;
        outs.iter().map(from_literal).collect()
    }

    /// Upload a tensor to a device-resident buffer. Hot-path optimization:
    /// buffers created once (e.g. stage parameters) are reused across many
    /// `execute_buffers` calls, skipping the per-call host→literal→device
    /// double copy of the literal path (EXPERIMENTS.md §Perf).
    pub fn to_buffer(&self, t: &Tensor) -> Result<xla::PjRtBuffer> {
        let buf = match t {
            Tensor::F32 { shape, data } => {
                self.client.buffer_from_host_buffer(data, shape, None)?
            }
            Tensor::I32 { shape, data } => {
                self.client.buffer_from_host_buffer(data, shape, None)?
            }
        };
        Ok(buf)
    }

    /// Execute on pre-staged device buffers; the tuple result is brought
    /// back to the host and decomposed.
    pub fn execute_buffers(
        &self,
        name: &str,
        args: &[&xla::PjRtBuffer],
    ) -> Result<Vec<Tensor>> {
        let exe = self
            .executables
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not loaded"))?;
        let out = exe.execute_b(args)?;
        let lit = first_result(name, &out)?.to_literal_sync()?;
        let elems = lit.to_tuple()?;
        self.check_arity(name, elems.len())?;
        elems.iter().map(from_literal).collect()
    }
}

/// PJRT returns results as per-device → per-output nesting; we run on one
/// device with tupled outputs, so take `[0][0]` — but checked: a misbehaving
/// plugin returning an empty set must surface as an error, not a panic.
fn first_result<'a>(
    name: &str,
    out: &'a [Vec<xla::PjRtBuffer>],
) -> Result<&'a xla::PjRtBuffer> {
    out.first()
        .and_then(|per_device| per_device.first())
        .ok_or_else(|| anyhow!("artifact '{name}' execution returned an empty result set"))
}

/// Convert a [`Tensor`] into an XLA literal.
pub fn to_literal(t: &Tensor) -> Result<xla::Literal> {
    let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
    let lit = match t {
        Tensor::F32 { data, .. } => xla::Literal::vec1(data.as_slice()),
        Tensor::I32 { data, .. } => xla::Literal::vec1(data.as_slice()),
    };
    Ok(lit.reshape(&dims)?)
}

/// Convert an XLA literal back into a [`Tensor`].
pub fn from_literal(l: &xla::Literal) -> Result<Tensor> {
    let shape = l.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    match shape.ty() {
        xla::ElementType::F32 => Ok(Tensor::from_vec(&dims, l.to_vec::<f32>()?)),
        xla::ElementType::S32 => Ok(Tensor::from_ivec(&dims, l.to_vec::<i32>()?)),
        other => bail!("unsupported artifact output element type {:?}", other),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A tiny HLO module in text form — lets the loader be tested without
    /// any python-produced artifacts.
    const ADD_HLO: &str = r#"HloModule add_test

ENTRY main {
  p0 = f32[2,2]{1,0} parameter(0)
  p1 = f32[2,2]{1,0} parameter(1)
  sum = f32[2,2]{1,0} add(p0, p1)
  ROOT out = (f32[2,2]{1,0}) tuple(sum)
}
"#;

    fn write_temp(name: &str, contents: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("fusionai_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join(name);
        std::fs::write(&p, contents).unwrap();
        p
    }

    #[test]
    fn load_and_execute_hlo_text() {
        let path = write_temp("add.hlo.txt", ADD_HLO);
        // The offline `xla` stub has no PJRT runtime — skip when the
        // client can't come up (the real crate exercises the full path).
        let Ok(mut rt) = Runtime::cpu() else {
            eprintln!("skipping: PJRT runtime unavailable");
            return;
        };
        rt.load_hlo_text("add", &path).unwrap();
        assert!(rt.has("add"));
        let a = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let b = Tensor::from_vec(&[2, 2], vec![10.0, 20.0, 30.0, 40.0]);
        let out = rt.run("add", &[a, b]).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].f(), &[11.0, 22.0, 33.0, 44.0]);
    }

    #[test]
    fn literal_roundtrip_f32_and_i32() {
        let t = Tensor::from_vec(&[2, 3], vec![1.0, -2.0, 3.5, 0.0, 9.0, -7.25]);
        let l = to_literal(&t).unwrap();
        assert_eq!(from_literal(&l).unwrap(), t);
        let ti = Tensor::from_ivec(&[4], vec![5, -3, 0, 127]);
        let li = to_literal(&ti).unwrap();
        assert_eq!(from_literal(&li).unwrap(), ti);
    }

    #[test]
    fn missing_artifact_errors() {
        let Ok(rt) = Runtime::cpu() else {
            eprintln!("skipping: PJRT runtime unavailable");
            return;
        };
        assert!(rt.run("nope", &[]).is_err());
    }

    #[test]
    fn empty_result_set_is_an_error() {
        let out: Vec<Vec<xla::PjRtBuffer>> = Vec::new();
        let err = first_result("embed_fwd", &out).unwrap_err().to_string();
        assert!(err.contains("empty result set"), "got: {err}");
        let out = vec![Vec::new()];
        assert!(first_result("embed_fwd", &out).is_err());
    }

    #[test]
    fn manifest_parsing() {
        let manifest = r#"{
            "preset": "gpt-tiny",
            "config": {"vocab": 256, "dim": 32, "stages": 3},
            "stages": ["embed", "block0", "head"],
            "artifacts": {
                "embed_fwd": {"file": "embed_fwd.hlo.txt", "n_outputs": 1},
                "head_bwd": {"file": "head_bwd.hlo.txt", "n_outputs": 4}
            },
            "stage_params": {
                "embed": [
                    {"name": "wte", "shape": [256, 32], "init": "normal", "std": 0.02},
                    {"name": "wpe", "shape": [16, 32], "init": "normal", "std": 0.02}
                ],
                "head": [
                    {"name": "lnf_g", "shape": [32], "init": "ones"},
                    {"name": "lnf_b", "shape": [32], "init": "zeros"}
                ]
            }
        }"#;
        let path = write_temp("manifest.json", manifest);
        let m = Manifest::load(&path).unwrap();
        assert_eq!(m.preset, "gpt-tiny");
        assert_eq!(m.config_usize("vocab"), Some(256));
        assert_eq!(m.stages, vec!["embed", "block0", "head"]);
        assert_eq!(m.artifacts.len(), 2);
        let embed = &m.stage_params["embed"];
        assert_eq!(embed[0].shape, vec![256, 32]);
        assert_eq!(embed[0].init, InitKind::Normal { std: 0.02 });
        let head = &m.stage_params["head"];
        assert_eq!(head[0].init, InitKind::Ones);
        let mut rng = Rng::new(0);
        let g = head[0].materialize(&mut rng);
        assert!(g.f().iter().all(|&v| v == 1.0));
    }
}
