//! Job / fleet configuration: a TOML-subset parser and the typed configs
//! the CLI and examples consume.
//!
//! The paper's broker receives a "job definition file" (§3.2); ours is TOML:
//!
//! ```toml
//! [job]
//! model = "bert-large"       # or gpt3-24x4096 / gpt-e2e / gpt-tiny
//! batches = 512
//! training = false
//!
//! [network]
//! bandwidth_mbps = 100.0
//! latency_ms = 10.0
//!
//! [[fleet]]
//! gpu = "RTX 3080"
//! count = 50
//! lambda = 0.5
//!
//! [[fleet]]
//! gpu = "H100"
//! count = 0
//! lambda = 0.5
//!
//! [recovery]                 # optional; supervised-trainer knobs (§3.2/§3.5)
//! ckpt_every = 10            # v2 recovery checkpoint cadence (0 = final only)
//! heartbeat_timeout_s = 60.0
//! hop_timeout_s = 30.0
//! max_recoveries = 2
//! backup_nodes = 2
//! recovery_backoff_ms = 50
//! faults = "kill:stage=1,step=7"   # deterministic fault injection spec
//! ```
//!
//! Supported TOML subset: `[section]`, `[[array-of-tables]]`,
//! `key = value` with string/float/int/bool values, `#` comments.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use crate::models::transformer::TransformerConfig;
use crate::perf::comm::LinkModel;
use crate::perf::gpus::{lookup, GpuSpec};

/// A parsed TOML value.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Num(f64),
    Bool(bool),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// One `[section]` (or one element of a `[[section]]` list).
pub type TomlTable = BTreeMap<String, TomlValue>;

/// Parsed document: plain sections + array-of-table sections.
#[derive(Debug, Default)]
pub struct TomlDoc {
    pub tables: BTreeMap<String, TomlTable>,
    pub arrays: BTreeMap<String, Vec<TomlTable>>,
}

/// Parse the TOML subset.
pub fn parse_toml(src: &str) -> Result<TomlDoc> {
    let mut doc = TomlDoc::default();
    enum Cur {
        None,
        Table(String),
        Array(String),
    }
    let mut cur = Cur::None;
    for (lineno, raw) in src.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line.strip_prefix("[[").and_then(|s| s.strip_suffix("]]")) {
            let name = name.trim().to_string();
            doc.arrays.entry(name.clone()).or_default().push(TomlTable::new());
            cur = Cur::Array(name);
        } else if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
            let name = name.trim().to_string();
            doc.tables.entry(name.clone()).or_default();
            cur = Cur::Table(name);
        } else if let Some(eq) = line.find('=') {
            let key = line[..eq].trim().to_string();
            let val = parse_value(line[eq + 1..].trim())
                .ok_or_else(|| anyhow!("line {}: bad value '{line}'", lineno + 1))?;
            let table = match &cur {
                Cur::None => bail!("line {}: key before any section", lineno + 1),
                Cur::Table(name) => doc.tables.get_mut(name).unwrap(),
                Cur::Array(name) => doc.arrays.get_mut(name).unwrap().last_mut().unwrap(),
            };
            table.insert(key, val);
        } else {
            bail!("line {}: cannot parse '{line}'", lineno + 1);
        }
    }
    Ok(doc)
}

fn parse_value(s: &str) -> Option<TomlValue> {
    if let Some(inner) = s.strip_prefix('"').and_then(|t| t.strip_suffix('"')) {
        return Some(TomlValue::Str(inner.to_string()));
    }
    match s {
        "true" => return Some(TomlValue::Bool(true)),
        "false" => return Some(TomlValue::Bool(false)),
        _ => {}
    }
    s.parse::<f64>().ok().map(TomlValue::Num)
}

/// One fleet entry: `count` devices of one GPU model.
#[derive(Debug, Clone)]
pub struct FleetEntry {
    pub gpu: GpuSpec,
    pub count: usize,
    pub lambda: f64,
}

/// Supervised-trainer recovery knobs — the optional `[recovery]` section.
/// Mirrors the corresponding [`crate::cluster::TrainConfig`] fields; absent
/// keys keep the trainer's defaults.
#[derive(Debug, Clone, PartialEq)]
pub struct RecoveryConfig {
    pub ckpt_every: usize,
    pub heartbeat_timeout_s: f64,
    pub hop_timeout_s: f64,
    pub max_recoveries: usize,
    pub backup_nodes: usize,
    pub recovery_backoff_ms: u64,
    /// Fault-injection spec (see `cluster::faults::FaultPlan::parse`);
    /// validated at config-parse time, empty = no faults.
    pub faults: String,
}

impl Default for RecoveryConfig {
    fn default() -> RecoveryConfig {
        RecoveryConfig {
            ckpt_every: 10,
            heartbeat_timeout_s: 60.0,
            hop_timeout_s: 30.0,
            max_recoveries: 2,
            backup_nodes: 2,
            recovery_backoff_ms: 50,
            faults: String::new(),
        }
    }
}

impl RecoveryConfig {
    /// Build from a parsed `[recovery]` table (missing keys → defaults).
    pub fn from_table(t: &TomlTable) -> Result<RecoveryConfig> {
        let d = RecoveryConfig::default();
        let num = |key: &str, dflt: f64| -> Result<f64> {
            match t.get(key) {
                None => Ok(dflt),
                Some(v) => {
                    v.as_f64().ok_or_else(|| anyhow!("[recovery] {key} must be a number"))
                }
            }
        };
        let cfg = RecoveryConfig {
            ckpt_every: num("ckpt_every", d.ckpt_every as f64)? as usize,
            heartbeat_timeout_s: num("heartbeat_timeout_s", d.heartbeat_timeout_s)?,
            hop_timeout_s: num("hop_timeout_s", d.hop_timeout_s)?,
            max_recoveries: num("max_recoveries", d.max_recoveries as f64)? as usize,
            backup_nodes: num("backup_nodes", d.backup_nodes as f64)? as usize,
            recovery_backoff_ms: num("recovery_backoff_ms", d.recovery_backoff_ms as f64)?
                as u64,
            faults: match t.get("faults") {
                None => String::new(),
                Some(v) => v
                    .as_str()
                    .ok_or_else(|| anyhow!("[recovery] faults must be a string"))?
                    .to_string(),
            },
        };
        if cfg.heartbeat_timeout_s <= 0.0 || cfg.hop_timeout_s <= 0.0 {
            bail!("[recovery] timeouts must be positive");
        }
        if !cfg.faults.is_empty() {
            // Surface a bad spec at parse time, not mid-run.
            crate::cluster::faults::FaultPlan::parse(&cfg.faults)?;
        }
        Ok(cfg)
    }
}

/// The typed experiment configuration.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub model: TransformerConfig,
    pub batches: usize,
    pub training: bool,
    pub link: LinkModel,
    pub fleet: Vec<FleetEntry>,
    /// `[recovery]` section; `None` when absent (trainer defaults apply).
    pub recovery: Option<RecoveryConfig>,
}

impl ExperimentConfig {
    /// Parse from TOML text.
    pub fn from_toml(src: &str) -> Result<ExperimentConfig> {
        let doc = parse_toml(src)?;
        let job = doc.tables.get("job").ok_or_else(|| anyhow!("missing [job]"))?;
        let model_name = job
            .get("model")
            .and_then(TomlValue::as_str)
            .ok_or_else(|| anyhow!("[job] needs model"))?;
        let model = model_by_name(model_name)?;
        let batches =
            job.get("batches").and_then(TomlValue::as_f64).unwrap_or(512.0) as usize;
        let training = job.get("training").and_then(TomlValue::as_bool).unwrap_or(false);
        let net = doc.tables.get("network");
        let bw = net
            .and_then(|t| t.get("bandwidth_mbps"))
            .and_then(TomlValue::as_f64)
            .unwrap_or(100.0);
        let lat =
            net.and_then(|t| t.get("latency_ms")).and_then(TomlValue::as_f64).unwrap_or(10.0);
        let mut fleet = Vec::new();
        for entry in doc.arrays.get("fleet").map(Vec::as_slice).unwrap_or(&[]) {
            let name = entry
                .get("gpu")
                .and_then(TomlValue::as_str)
                .ok_or_else(|| anyhow!("[[fleet]] needs gpu"))?;
            let gpu = lookup(name).ok_or_else(|| anyhow!("unknown GPU '{name}'"))?.clone();
            let count =
                entry.get("count").and_then(TomlValue::as_f64).unwrap_or(1.0) as usize;
            let lambda = entry.get("lambda").and_then(TomlValue::as_f64).unwrap_or(0.5);
            if count > 0 {
                fleet.push(FleetEntry { gpu, count, lambda });
            }
        }
        if fleet.is_empty() {
            bail!("config declares no fleet devices");
        }
        let recovery =
            doc.tables.get("recovery").map(RecoveryConfig::from_table).transpose()?;
        Ok(ExperimentConfig {
            model,
            batches,
            training,
            link: LinkModel::from_ms_mbps(lat, bw),
            fleet,
            recovery,
        })
    }

    pub fn total_devices(&self) -> usize {
        self.fleet.iter().map(|f| f.count).sum()
    }
}

/// Resolve a model preset by name.
pub fn model_by_name(name: &str) -> Result<TransformerConfig> {
    Ok(match name {
        "bert-large" => TransformerConfig::bert_large(),
        "gpt3-24x4096" => TransformerConfig::gpt3_24x4096(),
        "gpt-e2e" => TransformerConfig::gpt_e2e(),
        "gpt-tiny" => TransformerConfig::tiny(),
        other => bail!("unknown model preset '{other}'"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# the paper's headline comparison
[job]
model = "bert-large"
batches = 512
training = false

[network]
bandwidth_mbps = 1000.0
latency_ms = 5.0

[[fleet]]
gpu = "RTX 3080"
count = 50
lambda = 0.5

[[fleet]]
gpu = "H100"
count = 4
lambda = 0.5
"#;

    #[test]
    fn parses_sample() {
        let c = ExperimentConfig::from_toml(SAMPLE).unwrap();
        assert_eq!(c.model.name, "bert-large");
        assert_eq!(c.batches, 512);
        assert!(!c.training);
        assert_eq!(c.fleet.len(), 2);
        assert_eq!(c.total_devices(), 54);
        assert!((c.link.alpha - 0.005).abs() < 1e-12);
    }

    #[test]
    fn toml_subset_features() {
        let doc = parse_toml(
            "[a]\nx = 1.5 # comment\ny = \"s\"\nz = true\n[[b]]\nk = 1\n[[b]]\nk = 2\n",
        )
        .unwrap();
        assert_eq!(doc.tables["a"]["x"], TomlValue::Num(1.5));
        assert_eq!(doc.tables["a"]["y"], TomlValue::Str("s".into()));
        assert_eq!(doc.tables["a"]["z"], TomlValue::Bool(true));
        assert_eq!(doc.arrays["b"].len(), 2);
        assert_eq!(doc.arrays["b"][1]["k"], TomlValue::Num(2.0));
    }

    #[test]
    fn errors_are_informative() {
        assert!(parse_toml("x = 1").is_err()); // key before section
        assert!(parse_toml("[a]\nx =").is_err());
        let bad = "[job]\nmodel = \"nope\"\n[[fleet]]\ngpu = \"RTX 3080\"\ncount = 1";
        assert!(ExperimentConfig::from_toml(bad).is_err());
        let nofleet = "[job]\nmodel = \"gpt-tiny\"";
        assert!(ExperimentConfig::from_toml(nofleet).is_err());
    }

    #[test]
    fn recovery_section_is_optional_and_validated() {
        let c = ExperimentConfig::from_toml(SAMPLE).unwrap();
        assert!(c.recovery.is_none());

        let with = format!(
            "{SAMPLE}\n[recovery]\nckpt_every = 5\nmax_recoveries = 3\n\
             faults = \"kill:stage=1,step=7\"\n"
        );
        let c = ExperimentConfig::from_toml(&with).unwrap();
        let r = c.recovery.unwrap();
        assert_eq!(r.ckpt_every, 5);
        assert_eq!(r.max_recoveries, 3);
        assert_eq!(r.backup_nodes, RecoveryConfig::default().backup_nodes);
        assert_eq!(r.faults, "kill:stage=1,step=7");

        // A bad fault spec or non-positive timeout fails at parse time.
        let bad = format!("{SAMPLE}\n[recovery]\nfaults = \"explode:stage=1\"\n");
        assert!(ExperimentConfig::from_toml(&bad).is_err());
        let bad = format!("{SAMPLE}\n[recovery]\nhop_timeout_s = 0\n");
        assert!(ExperimentConfig::from_toml(&bad).is_err());
    }

    #[test]
    fn model_presets_resolve() {
        for name in ["bert-large", "gpt3-24x4096", "gpt-e2e", "gpt-tiny"] {
            assert!(model_by_name(name).is_ok());
        }
        assert!(model_by_name("llama").is_err());
    }
}
